"""The status/introspection surface: one JSON report for the whole server.

Replaces the warm-pool loop's ad-hoc prints with a machine-checkable
schema the CI smoke (and any operator dashboard) asserts against:

  * per-pool: the canonical config, the embedded ``Plan`` of the last
    decomposition (how backend/hierarchy resolved), the Session's full
    counter block, the warm/cold hit rate, and the tracked shape buckets;
  * per-artifact: name -> live version (+ size/axes);
  * server-wide: queue depth, intake counters (submitted/served/
    rejected_admission/rejected_queue/batches/coalesced), and the
    admission budget.

``validate_status`` is the schema gate — it raises with the missing/
malformed path, so the CI smoke failure names the drifted field.
"""
from __future__ import annotations

from typing import Any, Dict

STATUS_FORMAT = "repro.nucleus-server-status"
STATUS_VERSION = 1

# required keys and their types, by path — the schema the CI smoke pins
_TOP_KEYS = {"format": str, "version": int, "queue_depth": int,
             "admission_budget_bytes": int, "frontend": dict,
             "pools": list, "artifacts": dict}
_FRONTEND_KEYS = ("submitted", "served", "failed", "rejected_admission",
                  "rejected_queue", "batches", "coalesced")
_POOL_KEYS = {"config": dict, "plan": (dict, type(None)), "stats": dict,
              "hit_rate": float, "buckets": list,
              # builder telemetry of the pool's last decomposition (None
              # until one carries build_stats); sharded builds report
              # n_shards / chunks_per_shard / skew / exchange_bytes here
              "build": (dict, type(None))}
_POOL_STAT_KEYS = ("decompositions", "warm", "cold", "fallback", "updates",
                   "stream_warm", "stream_cold", "evictions", "prewarmed")
_ARTIFACT_KEYS = ("version", "n_r", "r", "s")


def status_report(frontend) -> Dict[str, Any]:
    """Snapshot the frontend + router into the status schema (pure reads
    under the respective stats locks — safe to call from any thread
    while the worker serves)."""
    with frontend._stats_lock:
        fstats = dict(frontend.stats)
    report = frontend.router.report()
    return {
        "format": STATUS_FORMAT,
        "version": STATUS_VERSION,
        "queue_depth": int(frontend.queue_depth),
        "admission_budget_bytes": int(frontend.admission_budget_bytes),
        "frontend": fstats,
        "pools": report["pools"],
        "artifacts": report["artifacts"],
    }


def validate_status(d: Dict[str, Any]) -> Dict[str, Any]:
    """Assert ``d`` matches the status schema; returns ``d``.

    Raises ``ValueError`` naming the first offending path — the CI smoke
    and the serve tests call this on every fetched report, so schema
    drift fails with the field's name instead of a downstream KeyError.
    """
    def fail(path: str, why: str):
        raise ValueError(f"status schema violation at {path}: {why}")

    for key, typ in _TOP_KEYS.items():
        if key not in d:
            fail(key, "missing")
        if not isinstance(d[key], typ):
            fail(key, f"expected {typ}, got {type(d[key]).__name__}")
    if d["format"] != STATUS_FORMAT:
        fail("format", f"expected {STATUS_FORMAT!r}, got {d['format']!r}")
    for key in _FRONTEND_KEYS:
        if not isinstance(d["frontend"].get(key), int):
            fail(f"frontend.{key}", "missing or non-integer")
    for i, pool in enumerate(d["pools"]):
        for key, typ in _POOL_KEYS.items():
            if key not in pool:
                fail(f"pools[{i}].{key}", "missing")
            if not isinstance(pool[key], typ):
                fail(f"pools[{i}].{key}",
                     f"expected {typ}, got {type(pool[key]).__name__}")
        for key in _POOL_STAT_KEYS:
            if not isinstance(pool["stats"].get(key), int):
                fail(f"pools[{i}].stats.{key}", "missing or non-integer")
        if pool["plan"] is not None and "backend" not in pool["plan"]:
            fail(f"pools[{i}].plan", "plan dict lacks 'backend'")
        if not 0.0 <= pool["hit_rate"] <= 1.0:
            fail(f"pools[{i}].hit_rate", f"out of [0,1]: {pool['hit_rate']}")
    for name, art in d["artifacts"].items():
        for key in _ARTIFACT_KEYS:
            if not isinstance(art.get(key), int):
                fail(f"artifacts[{name!r}].{key}", "missing or non-integer")
    return d
