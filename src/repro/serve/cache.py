"""The persistent warm path: compile caching + session manifests.

A warm ``Session`` dies with its process: every restart pays the full
XLA compile for each shape bucket before the pool is warm again — the
dominant serving cost, multiplied by every deploy.  Two pieces make the
pool survive restarts:

  * **Persistent compilation cache.**  ``init_persistent_cache(dir)``
    points jax's on-disk executable cache at ``dir`` (min-entry-size and
    min-compile-time gates opened so even small peel executables
    persist).  Compiles keyed on the same HLO — same padded shapes, same
    statics — are then disk loads in any later process.
  * **Session manifest.**  ``save_manifest``/``load_manifest`` persist
    ``Router.manifest()`` (one ``Session.manifest()`` per pool: the
    shape-class records, nothing graph-specific) as JSON next to the
    cache.  ``Router.prewarm(manifest)`` recreates each pool and runs
    every bucket's all-ghost twin through the engine, turning the disk
    cache into live jitted callables — the first post-restart
    same-bucket decompose is a warm hit, not a multi-second compile
    (the ``server`` bench lane records the >= 3x restart claim).

``init_persistent_cache`` degrades gracefully: a jax build without the
persistent-cache config options (or one that rejects them) logs and
returns False — serving continues with in-process warmth only.
"""
from __future__ import annotations

import json
import os
import warnings
from typing import Any, Dict, Optional

from ..core.session import MANIFEST_FORMAT

ROUTER_MANIFEST_FORMAT = "repro.nucleus-server-manifest"
ROUTER_MANIFEST_VERSION = 1
MANIFEST_BASENAME = "session_manifest.json"


def init_persistent_cache(cache_dir: str, *,
                          min_entry_size_bytes: int = -1,
                          min_compile_time_secs: float = 0.0) -> bool:
    """Enable jax's on-disk compilation cache at ``cache_dir``.

    Must run before the executables of interest compile (ideally at
    process start, right after ``launch.platform.setup_platform``).  The
    default gates are opened fully (``-1`` / ``0.0``): peel executables
    for small shape buckets compile fast enough that jax's stock
    thresholds would skip exactly the entries a restarted server needs.
    Returns True if the cache was wired, False (with a warning) when
    this jax build lacks the config knobs.
    """
    import jax

    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir",
                          os.fspath(cache_dir))
        jax.config.update("jax_persistent_cache_min_entry_size_bytes",
                          int(min_entry_size_bytes))
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          float(min_compile_time_secs))
    except Exception as e:  # older jax: knob missing/renamed — degrade
        warnings.warn(
            f"persistent compilation cache unavailable ({e!r}); serving "
            f"continues with in-process warmth only", RuntimeWarning)
        return False
    return True


def router_manifest(router) -> Dict[str, Any]:
    """One manifest per pool, wrapped in the server envelope (the
    restart contract: everything ``Router.prewarm`` needs, nothing
    graph- or tenant-specific)."""
    with router._lock:
        pools = list(router._pools.values())
    return {"format": ROUTER_MANIFEST_FORMAT,
            "version": ROUTER_MANIFEST_VERSION,
            "pools": [sess.manifest() for sess in pools]}


def prewarm_router(router, manifest: Dict[str, Any]) -> int:
    """Recreate every manifest pool on ``router`` and prewarm its shape
    buckets; returns the total bucket count prewarmed.  Pools that
    already exist prewarm in place (idempotent across repeated calls —
    already-registered buckets are skipped by ``Session.prewarm``)."""
    from ..core.api import NucleusConfig

    if manifest.get("format") != ROUTER_MANIFEST_FORMAT:
        raise ValueError(
            f"not a server manifest: format={manifest.get('format')!r} "
            f"(expected {ROUTER_MANIFEST_FORMAT!r}) — regenerate it with "
            f"serve.cache.router_manifest()")
    total = 0
    for pool_manifest in manifest.get("pools", []):
        if pool_manifest.get("format") != MANIFEST_FORMAT:
            raise ValueError(
                f"malformed pool entry: format="
                f"{pool_manifest.get('format')!r} — the manifest was "
                f"truncated or hand-edited; regenerate it")
        config = NucleusConfig.from_dict(pool_manifest["config"])
        sess = router.pool(config)
        total += sess.prewarm(pool_manifest)
    return total


def save_manifest(router, path: str) -> str:
    """Serialize ``router_manifest(router)`` to ``path`` (a directory
    gets ``session_manifest.json`` inside it).  Returns the file path."""
    if os.path.isdir(path):
        path = os.path.join(path, MANIFEST_BASENAME)
    blob = router_manifest(router)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(blob, f, sort_keys=True, indent=1)
        f.write("\n")
    os.replace(tmp, path)  # atomic: a crash never leaves a torn manifest
    return path


def load_manifest(path: str) -> Optional[Dict[str, Any]]:
    """Read a manifest written by ``save_manifest``; a directory resolves
    to ``session_manifest.json`` inside it.  Returns None when the file
    does not exist (a first boot), raises on a malformed one."""
    if os.path.isdir(path):
        path = os.path.join(path, MANIFEST_BASENAME)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        blob = json.load(f)
    if blob.get("format") != ROUTER_MANIFEST_FORMAT:
        raise ValueError(
            f"{path}: not a server manifest (format="
            f"{blob.get('format')!r}); delete it or regenerate with "
            f"save_manifest()")
    return blob
