"""Plan-aware request routing: one ``Session`` pool per config class.

The multi-tenant server's core problem is executable reuse across
*heterogeneous* traffic: tenants submit different graphs under different
(r, s)/method/hierarchy axes, and a naive Session-per-request (or one
Session hardcoded to a single config — the old ``serve --warm-pool``)
either recompiles constantly or serves one tenant class only.  The
``Router`` solves it in two layers:

  * **Pool keying.**  Each request's config axes are *canonicalized*
    (axes the compiled executable never reads are pinned to defaults —
    e.g. ``delta`` under ``method='exact'``) and the canonical config
    keys a pool of warm ``Session``s.  Near-identical tenants — same
    axes, different graphs — land in ONE session, where the Session's
    pow2 shape buckets collapse them further onto shared executables.
  * **Introspection.**  Per pool entry the router reports the embedded
    ``Plan`` of the last decomposition (how backend/hierarchy resolved),
    the warm/cold hit rates out of ``Session.stats``, and the tracked
    shape buckets — the status surface (``serve.status``) serializes
    this next to queue/admission counters.

Named live artifacts ride the same pools: ``route()`` publishes a
decomposition under ``Request.artifact``, ``update()`` applies a
``GraphDelta`` through ``Session.update`` (stream buckets and all) and
re-publishes the successor under the same name with ``version + 1``.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Tuple

from ..core.api import (Decomposition, NucleusConfig, plan_config,
                        resolve_problem)
from ..core.incidence import NucleusProblem
from ..core.session import Session
from ..core.streaming import GraphDelta

# config defaults the canonicalizer pins dead axes back to
_DEFAULTS = NucleusConfig()


@dataclasses.dataclass
class Request:
    """One unit of routed work.

    ``graph`` is a ``Graph`` or prebuilt ``NucleusProblem`` (decompose
    requests); ``update`` is a ``GraphDelta`` against the named live
    artifact ``artifact`` (update requests — ``graph`` must be None).
    ``artifact`` on a decompose request publishes the result under that
    name so later queries/updates can address it."""

    graph: Any = None
    r: int = 2
    s: int = 3
    method: str = "exact"
    hierarchy: str = "fused"
    backend: str = "dense"
    delta: float = 0.1
    use_pallas: Optional[bool] = None
    build: str = "eager"
    build_shards: Optional[int] = None
    memory_budget_bytes: Optional[int] = None
    artifact: str = ""
    update: Optional[GraphDelta] = None

    @property
    def kind(self) -> str:
        return "update" if self.update is not None else "decompose"

    def config(self) -> NucleusConfig:
        return NucleusConfig(r=self.r, s=self.s, method=self.method,
                             hierarchy=self.hierarchy, backend=self.backend,
                             delta=self.delta, use_pallas=self.use_pallas,
                             build=self.build, build_shards=self.build_shards,
                             memory_budget_bytes=self.memory_budget_bytes)


def canonical_config(config: NucleusConfig) -> NucleusConfig:
    """Pin axes the resolved executable never reads, so near-identical
    tenants share one pool (and its compiled executables) instead of
    fragmenting on irrelevant knobs: ``delta`` only matters under
    ``method='approx'``; build knobs shape the *builder*, not the peel
    executable, and prebuilt problems skip them entirely."""
    if config.method == "exact" and config.delta != _DEFAULTS.delta:
        config = dataclasses.replace(config, delta=_DEFAULTS.delta)
    return config


def pool_key(config: NucleusConfig) -> Tuple:
    """Hashable identity of a canonical config (the mesh, a process-local
    handle, is excluded by ``to_dict``)."""
    return tuple(sorted(canonical_config(config).to_dict().items(),
                        key=lambda kv: kv[0]))


class Router:
    """Route heterogeneous requests through per-config ``Session`` pools.

    Thread-safety contract: pool creation, artifact publication, and all
    bookkeeping are lock-guarded, but *engine* access (decompose/update)
    is expected to be single-writer — the ``Frontend`` drains its queue
    from one worker thread.  Calling ``route`` concurrently is safe (the
    Sessions' own stats locks keep counters exact) but forfeits the
    batching the frontend provides.
    """

    def __init__(self, *, bucket_floor: Optional[int] = None,
                 bucket_cap: Optional[int] = None):
        self._session_kw: Dict[str, int] = {}
        if bucket_floor is not None:
            self._session_kw["bucket_floor"] = int(bucket_floor)
        if bucket_cap is not None:
            self._session_kw["bucket_cap"] = int(bucket_cap)
        self._lock = threading.Lock()
        self._pools: Dict[Tuple, Session] = {}
        self._last_plan: Dict[Tuple, Any] = {}
        # pool -> build_stats of the last decomposition whose problem
        # carried them (how the incidence structure was built: sharded
        # chunk/skew/exchange telemetry rides the status surface)
        self._last_build: Dict[Tuple, Dict[str, Any]] = {}
        # name -> (artifact, pool_key); versions live on the artifact
        self._artifacts: Dict[str, Tuple[Decomposition, Tuple]] = {}

    # -- pools -------------------------------------------------------------
    def pool(self, config: NucleusConfig) -> Session:
        """The warm Session serving ``config``'s canonical class (created
        on first use)."""
        key = pool_key(config)
        with self._lock:
            sess = self._pools.get(key)
            if sess is None:
                sess = Session(canonical_config(config), **self._session_kw)
                self._pools[key] = sess
            return sess

    def resolve(self, request: Request
                ) -> Tuple[NucleusProblem, NucleusConfig]:
        """Build/adopt the request's problem under its canonical config —
        the shared prologue ``Frontend.submit`` runs for admission (the
        padded budget estimate needs the problem's shapes)."""
        if request.kind != "decompose":
            raise ValueError("resolve() is for decompose requests; "
                             "updates address a named artifact")
        return resolve_problem(request.graph,
                               canonical_config(request.config()))

    # -- routed work -------------------------------------------------------
    def route(self, request: Request) -> Decomposition:
        """Execute one request on its pool: decompose (publishing under
        ``request.artifact`` if named) or update-in-place of a named live
        artifact."""
        if request.kind == "update":
            return self.update(request.artifact, request.update)
        problem, config = self.resolve(request)
        sess = self.pool(config)
        dec = sess.decompose(problem)
        self._record(config, dec, request.artifact)
        return dec

    def route_many(self, requests: List[Request],
                   problems: Optional[List[NucleusProblem]] = None
                   ) -> List[Decomposition]:
        """Same-pool batch: ``requests`` must share one canonical config
        (the frontend coalesces by pool+bucket before calling).  Prebuilt
        ``problems`` (from admission-time ``resolve``) skip a rebuild."""
        if not requests:
            return []
        config = canonical_config(requests[0].config())
        key = pool_key(config)
        for req in requests[1:]:
            if pool_key(canonical_config(req.config())) != key:
                raise ValueError("route_many() requires same-pool requests"
                                 " — coalesce by pool first")
        sess = self.pool(config)
        if problems is None:
            problems = [self.resolve(r)[0] for r in requests]
        decs = sess.decompose_many(problems)
        for req, dec in zip(requests, decs):
            self._record(config, dec, req.artifact)
        return decs

    def _record(self, config: NucleusConfig, dec: Decomposition,
                artifact: str) -> None:
        key = pool_key(config)
        with self._lock:
            if dec.plan is not None:
                self._last_plan[key] = dec.plan
            if dec.problem is not None and dec.problem.build_stats:
                self._last_build[key] = dict(dec.problem.build_stats)
            if artifact:
                dec.name = artifact
                self._artifacts[artifact] = (dec, key)

    # -- named live artifacts ----------------------------------------------
    def artifact(self, name: str) -> Decomposition:
        with self._lock:
            entry = self._artifacts.get(name)
        if entry is None:
            raise KeyError(
                f"no live artifact named {name!r}; publish one by routing "
                f"a decompose request with artifact={name!r}")
        return entry[0]

    def update(self, name: str, delta: GraphDelta) -> Decomposition:
        """Incrementally advance the named artifact one edit generation
        through its pool's ``Session.update`` (stream-bucket accounting
        included); the successor replaces the published artifact."""
        with self._lock:
            entry = self._artifacts.get(name)
        if entry is None:
            raise KeyError(
                f"no live artifact named {name!r} to update; publish it "
                f"first (decompose with artifact={name!r})")
        dec, key = entry
        with self._lock:
            sess = self._pools[key]
        new = sess.update(dec, delta)
        new.name = name
        with self._lock:
            self._artifacts[name] = (new, key)
        return new

    # -- introspection -----------------------------------------------------
    def report(self) -> Dict[str, Any]:
        """Per-pool plan + hit rates + buckets, per-artifact versions —
        the router's slice of the status surface (``serve.status`` wraps
        it with queue/admission counters and the JSON envelope)."""
        with self._lock:
            pools = list(self._pools.items())
            plans = dict(self._last_plan)
            builds = dict(self._last_build)
            artifacts = dict(self._artifacts)

        def bucket_row(sess: Session, k: Tuple, v: int) -> Dict[str, Any]:
            # decompose/sharded buckets carry shape-class meta; everything
            # else is a stream-stage key (see Session._bucket_hit)
            kind = sess._bucket_meta.get(k, {}).get("kind")
            if kind == "decompose":
                return {"n_r_pad": k[4], "n_s_pad": k[5], "count": int(v)}
            if kind == "sharded":
                return {"n_r_pad": k[4], "n_s_pad": k[5],
                        "shards": int(k[8]), "count": int(v)}
            return {"stream_stage": str(k[0]), "count": int(v)}

        pool_rows = []
        for key, sess in pools:
            with sess._stats_lock:
                stats = {k: v for k, v in sess.stats.items()
                         if k != "buckets"}
                buckets = [bucket_row(sess, k, v)
                           for k, v in sess.stats["buckets"].items()]
            warm, cold = stats["warm"], stats["cold"]
            plan = plans.get(key)
            pool_rows.append({
                "config": sess.config.to_dict(),
                "plan": None if plan is None else plan.to_dict(),
                "stats": stats,
                "hit_rate": warm / max(warm + cold, 1),
                "buckets": buckets,
                "build": builds.get(key),
            })
        artifact_rows = {
            name: {"version": dec.version, "n_r": dec.n_r,
                   "r": dec.config.r, "s": dec.config.s}
            for name, (dec, _key) in artifacts.items()}
        return {"pools": pool_rows, "artifacts": artifact_rows}
